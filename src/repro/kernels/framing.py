"""Pallas TPU kernel: one-pass payload framing for fused wire hops.

``fuse_payload`` (transport/codecs.py) turns a packed payload pytree into
ONE contiguous uint8 buffer so each pipeline tick / DP ring hop costs a
single collective launch.  The jnp path builds that buffer with a
``concatenate`` over the bitcast leaves — XLA materializes every operand
and then copies the lot into a fresh buffer, an extra HBM round-trip on
every hop's send path.  The kernel here writes each leaf directly into its
static byte offset of the hop buffer in one pass (and the inverse slices
each leaf back out), so framing is one kernel instead of a concat chain.

The per-leaf dtype->uint8 bitcasts stay in XLA (they are layout metadata,
not data movement; Mosaic has no size-changing bitcast) — the kernel sees
only flat uint8 segments, so the framed buffer is BYTE-IDENTICAL to the
concat path by construction (asserted in tests/test_codec_kernels.py).
Dispatch lives in ``fuse_payload`` / ``unfuse_payload`` behind
``_use_pallas_wire()`` with a VMEM-residency guard; multi-leaf payloads
only (a single leaf needs no framing at all).
"""
from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The whole hop buffer is resident twice (segments + output); stay well
# under the ~16 MB of VMEM.
FRAME_MAX_BYTES = 4 * 1024 * 1024


def _frame_kernel(*refs, sizes: Sequence[int]):
    o_ref = refs[-1]
    off = 0
    for r, nb in zip(refs[:-1], sizes):
        o_ref[:, off:off + nb] = r[...]
        off += nb


def _unframe_kernel(b_ref, *o_refs, sizes: Sequence[int]):
    off = 0
    for r, nb in zip(o_refs, sizes):
        r[...] = b_ref[:, off:off + nb]
        off += nb


def frame_parts(parts: List[jnp.ndarray], *,
                interpret: bool | None = None) -> jnp.ndarray:
    """Concatenate flat uint8 leaf segments into one hop buffer with a
    single Pallas kernel — byte-identical to ``jnp.concatenate(parts)``."""
    assert all(p.dtype == jnp.uint8 and p.ndim == 1 for p in parts), parts
    parts = [p for p in parts if p.size]
    sizes = tuple(int(p.size) for p in parts)
    total = sum(sizes)
    if len(parts) < 2:
        return parts[0] if parts else jnp.zeros((0,), jnp.uint8)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    buf = pl.pallas_call(
        functools.partial(_frame_kernel, sizes=sizes),
        out_shape=jax.ShapeDtypeStruct((1, total), jnp.uint8),
        interpret=interpret,
    )(*[p.reshape(1, -1) for p in parts])
    return buf.reshape(-1)


def unframe_parts(buf: jnp.ndarray, sizes: Sequence[int], *,
                  interpret: bool | None = None) -> List[jnp.ndarray]:
    """Inverse of :func:`frame_parts`: slice the hop buffer back into flat
    uint8 segments of the given byte ``sizes`` (zero-size entries come back
    as empty arrays without touching the kernel)."""
    assert buf.dtype == jnp.uint8 and buf.ndim == 1, (buf.dtype, buf.shape)
    live = [nb for nb in sizes if nb]
    if len(live) < 2:
        out, off = [], 0
        for nb in sizes:
            out.append(buf[off:off + nb])
            off += nb
        return out
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    segs = pl.pallas_call(
        functools.partial(_unframe_kernel, sizes=live),
        out_shape=[jax.ShapeDtypeStruct((1, nb), jnp.uint8) for nb in live],
        interpret=interpret,
    )(buf.reshape(1, -1))
    segs = iter(segs)
    return [next(segs).reshape(-1) if nb else jnp.zeros((0,), jnp.uint8)
            for nb in sizes]
