"""Pallas TPU kernels: fused 4-bit quantize->scale->pack and the inverse.

The q4 wire format (transport/codecs.py) packs two 4-bit codes per uint8 —
byte j of a row is ``code[2j] | code[2j+1] << 4`` — with PER-TENSOR
min/scale and one zero pad code when the feature dim is odd.  The pure-jnp
path materializes the dense code tensor, the padded copy, the even/odd
strided slices and the shifted OR: five elementwise HBM round-trips on
exactly the tensor compression is meant to shrink.  The kernels here do
one each way: ``pack`` reads a row block into VMEM once, quantizes, pairs
and packs in-register (the odd-n pad is a single lane of zero codes
appended IN-KERNEL — HBM never sees a padded copy of x) and writes the
half-width byte tensor once; ``unpack`` splits nibbles, dequantizes and
writes the dense rows in one pass.

Scales stay per-tensor (paper Sec. 2.2), so the packed bytes are
BIT-IDENTICAL to the jnp path: the global min/max runs as one XLA reduce
before the kernel (min/max are associative — the reduction shape cannot
change the result), and the kernel consumes the two scalars as (1, 1)
operands.  Bytes-on-wire never change.  The ``unpack`` dequant
(``codes * scale + min``) may differ from ``dequantize_kbit`` by at most
1 ulp where the compiler contracts the multiply-add into an FMA (a
strictly-more-precise rounding).  Parity — including odd feature dims —
is asserted in tests/test_codec_kernels.py; the wire dispatch lives in
``transport/codecs.py`` behind ``_use_pallas_wire()``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import full_row_block

_LEVELS = 15.0


def _pack4_kernel(x_ref, mn_ref, sc_ref, o_ref, *, n: int):
    x = x_ref[...].astype(jnp.float32)                  # (bm, n)
    mn = mn_ref[0, 0]
    sc = sc_ref[0, 0]
    codes = jnp.clip(jnp.round((x - mn) / sc), 0.0, _LEVELS)
    if n % 2:                                           # in-kernel pad lane
        codes = jnp.pad(codes, ((0, 0), (0, 1)))
    pair = codes.reshape(codes.shape[0], -1, 2)
    even = pair[:, :, 0].astype(jnp.uint8)
    odd = pair[:, :, 1].astype(jnp.uint8)
    o_ref[...] = even | (odd << 4)


def _unpack4_kernel(p_ref, mn_ref, sc_ref, o_ref, *, n: int):
    p = p_ref[...]                                      # (bm, h) uint8
    mn = mn_ref[0, 0]
    sc = sc_ref[0, 0]
    even = (p & 0xF).astype(jnp.float32)
    odd = (p >> 4).astype(jnp.float32)
    codes = jnp.stack([even, odd], axis=-1).reshape(p.shape[0], -1)[:, :n]
    o_ref[...] = (codes * sc + mn).astype(o_ref.dtype)


def _minmax_scalars(flat):
    """Per-tensor (min, scale) — the same formula as quantize_kbit
    (axis=None), computed as one XLA reduce over the f32 input."""
    mn = jnp.min(flat)
    span = jnp.max(flat) - mn
    sc = jnp.where(span > 0, span / _LEVELS, jnp.ones_like(span))
    return mn, sc


def pack4_wire(flat: jnp.ndarray, *, interpret: bool | None = None):
    """flat: (M, N) float32.  Returns ``(packed uint8 (M, ceil(N/2)),
    min (), scale ())`` — bit-identical to the jnp q4 wire format."""
    assert flat.ndim == 2 and flat.dtype == jnp.float32, (
        flat.shape, flat.dtype)
    m, n = flat.shape
    h = (n + 1) // 2
    bm = full_row_block(m, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mn, sc = _minmax_scalars(flat)
    packed = pl.pallas_call(
        functools.partial(_pack4_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.uint8),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        interpret=interpret,
    )(flat, mn.reshape(1, 1), sc.reshape(1, 1))
    return packed, mn, sc


def unpack4_wire(packed: jnp.ndarray, mn, sc, n: int, dtype=jnp.float32, *,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Inverse of :func:`pack4_wire`: (M, ceil(n/2)) uint8 -> (M, n)
    ``dtype`` — one fused unpack->dequant pass, pad column dropped
    in-kernel."""
    assert packed.ndim == 2 and packed.dtype == jnp.uint8, (
        packed.shape, packed.dtype)
    m, h = packed.shape
    assert h == (n + 1) // 2, (h, n)
    bm = full_row_block(m, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_unpack4_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, h), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=interpret,
    )(packed, jnp.asarray(mn, jnp.float32).reshape(1, 1),
      jnp.asarray(sc, jnp.float32).reshape(1, 1))
