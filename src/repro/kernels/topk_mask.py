"""Pallas TPU kernel: block-local TopK sparsification mask.

TPU adaptation of the paper's TopK operator (DESIGN.md §4): a global TopK
needs a full sort (hostile to the VPU and to VMEM locality), so each
(bm, bn) tile selects its own top ceil(k_frac*bn) entries PER ROW via a
fixed-iteration threshold bisection on |x| — pure vector compares/reductions,
no sort, never leaves VMEM.  Convergence parity of block-local vs exact
global TopK is shown empirically in benchmarks/table2_topk.py.

The bisection keeps the invariant count(|x| >= hi) <= k <= count(|x| >= lo);
after ITERS=24 fp32 halvings ``lo`` sits within one ulp-scale interval of the
k-th largest magnitude, and the emitted mask is ``|x| >= lo`` (>= k kept,
ties included).  kernels/ref.py replicates the arithmetic exactly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ITERS = 24


def _topk_kernel(x_ref, o_ref, *, k: int, iters: int = ITERS):
    x = x_ref[...]
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)
    kf = jnp.float32(k)
    for _ in range(iters):                       # static unroll (VPU loop)
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.float32), axis=1,
                      keepdims=True)
        gt = cnt > kf
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    mask = mag >= lo
    o_ref[...] = jnp.where(mask, x, jnp.zeros_like(x))


def topk_block(x: jnp.ndarray, k_frac: float, *, block=(256, 512),
               interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, N) with N % bn == 0.  Keeps ~k_frac per row per tile."""
    assert x.ndim == 2, x.shape
    m, n = x.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, (bm, bn))
    k = max(1, int(math.ceil(k_frac * bn)))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)
