"""jit'd public wrappers around the Pallas kernels.

Handle arbitrary-rank boundary tensors (flatten to 2D per example), choose
tile shapes that divide the feature dim, fall back to the jnp reference when
no 128-multiple tiling exists (e.g. odd smoke-test widths), and provide a
straight-through custom_vjp so the kernels can sit INSIDE a compression
boundary's forward pass.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.quantize import quant_dequant as _qdq_pallas
from repro.kernels.tiling import lane_block as _pick_bn
from repro.kernels.tiling import pow2_row_block
from repro.kernels.topk_mask import topk_block as _topk_pallas


def _to_2d(x):
    b = x.shape[0]
    return x.reshape(b, -1)


@functools.partial(jax.jit, static_argnums=(1,))
def quant_dequant_op(x, bits: int):
    """Per-tile fused quant-dequant of a boundary tensor (any rank)."""
    flat = _to_2d(x)
    m, n = flat.shape
    bn = _pick_bn(n)
    if bn is None:
        return ref.quant_dequant_ref(flat, bits, block=(m, n)).reshape(x.shape)
    bm = pow2_row_block(m)                  # O(1); the old `while m % bm:
    y = _qdq_pallas(flat, bits, block=(bm, bn))  # bm -= 1` walked O(m)
    return y.reshape(x.shape)


@functools.partial(jax.jit, static_argnums=(1,))
def topk_block_op(x, k_frac: float):
    """Block-local TopK of a boundary tensor (any rank)."""
    flat = _to_2d(x)
    m, n = flat.shape
    bn = _pick_bn(n)
    if bn is None:
        return ref.topk_block_ref(flat, k_frac, block=(m, n)).reshape(x.shape)
    bm = pow2_row_block(m)
    y = _topk_pallas(flat, k_frac, block=(bm, bn))
    return y.reshape(x.shape)


# straight-through estimators (compression sits in a custom_vjp boundary;
# these make the kernels usable stand-alone too)

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quant_dequant_st(x, bits: int):
    return quant_dequant_op(x, bits)


quant_dequant_st.defvjp(
    lambda x, bits: (quant_dequant_op(x, bits), None),
    lambda bits, _, g: (g,))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def topk_block_st(x, k_frac: float):
    return topk_block_op(x, k_frac)


topk_block_st.defvjp(
    lambda x, k: (topk_block_op(x, k), None),
    lambda k, _, g: (g,))
