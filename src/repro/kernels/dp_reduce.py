"""Pallas TPU kernel: fused receive-side decode+sum for the DP ring.

After the ``ppermute`` ring (transport/collectives.py) every replica holds
``slots`` — the fused uint8 hop buffers of all ``dp`` source ranks, stacked
in SOURCE-RANK order.  The jnp path then runs, per source rank and per
parameter leaf, an unfuse slice + bitcast + dequantize + add: O(dp * leaves)
kernel launches and a dense f32 HBM round-trip per step, on the receive path
of every ring hop.  The kernel here does the whole thing in one launch: for
each leaf it walks the ``dp`` byte segments at their static offsets,
decodes the uint8 codes in-register (q8 bytes or q4 nibble pairs, the same
``codes * scale + min`` dequant as ``dequantize_kbit``) and accumulates in
a STATIC source-rank-ordered fold.  The fold association is fixed and every
replica executes the identical program, so all replicas still compute a
bitwise-identical reduced gradient — the DP acceptance invariant, asserted
in tests/test_codec_kernels.py.  Against the unfused XLA reference loop the
dequant may differ by at most 1 ulp where the compiler contracts the
multiply-add into an FMA (a strictly-more-precise rounding; the tests pin
this bound).

The per-source per-leaf (min, scale) f32 scalars are extracted from the
buffer bytes by XLA bitcasts beforehand (Mosaic has no size-changing
bitcast) and ride into the kernel as one ``(dp, 2 * leaves)`` operand.
``build_decode_plans`` validates the payload layout and returns ``None``
whenever this kernel does not apply (raw/TopK/per-tile payloads, empty
leaves, VMEM overflow) — the caller then keeps the reference loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# slots + meta + f32 accumulators all resident at once.
DECODE_MAX_BYTES = 4 * 1024 * 1024

_Q8_KEYS = frozenset(("codes", "min", "scale"))
_Q4_KEYS = frozenset(("codes4", "min", "scale"))


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static byte layout of one leaf's payload inside the fused buffer:
    ``kind`` q8/q4, codes at ``[off, off + nbytes)``, the f32 (min, scale)
    pair at ``[meta_off, meta_off + 8)``, dense feature count ``n``."""
    kind: str
    off: int
    nbytes: int
    meta_off: int
    n: int


def build_decode_plans(structs, leaf_shapes) -> Optional[List[LeafPlan]]:
    """Byte-layout plans for a list of per-leaf payload structs (the
    ``eval_shape`` dicts ``fuse_payload`` flattens), or ``None`` when the
    fused kernel does not apply.  Offsets follow ``jax.tree.leaves`` order
    — per-dict keys sorted, so codes always precede min/scale."""
    if len(structs) != len(leaf_shapes):
        return None
    plans, off = [], 0
    for s, shape in zip(structs, leaf_shapes):
        if not isinstance(s, dict):
            return None                      # raw passthrough (codec none)
        keys = frozenset(s)
        if keys == _Q8_KEYS:
            kind = "q8"
            codes = s["codes"]
        elif keys == _Q4_KEYS:
            kind = "q4"
            codes = s["codes4"]
        else:
            return None                      # topk / per-tile q8
        n = 1
        for d in shape:
            n *= d
        nbytes = 1
        for d in codes.shape:
            nbytes *= d
        if (n == 0 or codes.dtype != jnp.uint8
                or s["min"].shape != () or s["scale"].shape != ()
                or jnp.dtype(s["min"].dtype).itemsize != 4
                or jnp.dtype(s["scale"].dtype).itemsize != 4):
            return None
        expect = (n + 1) // 2 if kind == "q4" else n
        if nbytes != expect:
            return None
        plans.append(LeafPlan(kind, off, nbytes, off + nbytes, n))
        off += nbytes + 8
    return plans


def extract_meta(slots: jnp.ndarray, plans: Sequence[LeafPlan]):
    """(dp, nbytes) uint8 slots -> (dp, 2 * leaves) f32 of per-source
    (min, scale) pairs, bitcast straight from the payload bytes."""
    dp = slots.shape[0]
    cols = []
    for p in plans:
        for o in (p.meta_off, p.meta_off + 4):
            cols.append(jax.lax.bitcast_convert_type(
                slots[:, o:o + 4], jnp.float32))
    return jnp.stack(cols, axis=1).reshape(dp, 2 * len(plans))


def _decode_sum_kernel(slots_ref, meta_ref, *o_refs,
                       plans: Sequence[LeafPlan], dp: int):
    for li, p in enumerate(plans):
        acc = None
        for s in range(dp):                  # static rank-ordered fold
            seg = slots_ref[s:s + 1, p.off:p.off + p.nbytes]
            mn = meta_ref[s, 2 * li]
            sc = meta_ref[s, 2 * li + 1]
            if p.kind == "q8":
                codes = seg.astype(jnp.float32)
            else:
                even = (seg & 0xF).astype(jnp.float32)
                odd = (seg >> 4).astype(jnp.float32)
                codes = jnp.stack([even, odd],
                                  axis=-1).reshape(1, -1)[:, :p.n]
            d = codes * sc + mn
            acc = d if acc is None else acc + d
        o_refs[li][...] = acc


def decode_fits(plans: Sequence[LeafPlan], dp: int,
                budget: int = DECODE_MAX_BYTES) -> bool:
    nbytes = plans[-1].meta_off + 8 if plans else 0
    dense = sum(p.n for p in plans) * 4
    return dp * nbytes + dense + dp * len(plans) * 8 <= budget


def decode_sum_fused(slots: jnp.ndarray, plans: Sequence[LeafPlan],
                     dp: int, *,
                     interpret: bool | None = None) -> List[jnp.ndarray]:
    """slots: (dp, nbytes) uint8 source-rank-ordered hop buffers.  Returns
    one (1, n) float32 rank-summed dense gradient per leaf plan — the same
    static rank-ordered association as the unfuse->dequantize->add
    reference loop (identical on every replica; <= 1 ulp of FMA rounding
    vs the unfused loop)."""
    assert slots.ndim == 2 and slots.dtype == jnp.uint8, (
        slots.shape, slots.dtype)
    assert slots.shape[0] == dp, (slots.shape, dp)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    meta = extract_meta(slots, plans)
    out = pl.pallas_call(
        functools.partial(_decode_sum_kernel, plans=tuple(plans), dp=dp),
        out_shape=[jax.ShapeDtypeStruct((1, p.n), jnp.float32)
                   for p in plans],
        interpret=interpret,
    )(slots, meta)
    return list(out)
