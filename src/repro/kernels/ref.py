"""Pure-jnp oracles for every Pallas kernel (bit-exact where stated)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _tile_view(x, bm, bn):
    m, n = x.shape
    return x.reshape(m // bm, bm, n // bn, bn)


def quant_dequant_ref(x: jnp.ndarray, bits: int, block=(256, 256)):
    """Per-tile min-max quant-dequant; mirrors kernels/quantize.py exactly."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    levels = (1 << bits) - 1
    t = _tile_view(x, bm, bn).astype(jnp.float32)
    xmin = t.min(axis=(1, 3), keepdims=True)
    xmax = t.max(axis=(1, 3), keepdims=True)
    span = xmax - xmin
    scale = jnp.where(span > 0, span / levels, 1.0)
    codes = jnp.clip(jnp.round((t - xmin) / scale), 0.0, float(levels))
    out = (codes * scale + xmin).astype(x.dtype)
    return _untile(out, m, n)


def _untile(t, m, n):
    # t: (gm, bm, gn, bn) laid out as produced by _tile_view (no transpose)
    return t.reshape(m, n)


def quantize_wire_ref(x, bits: int, block=(256, 256)):
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    levels = (1 << bits) - 1
    t = _tile_view(x, bm, bn).astype(jnp.float32)
    xmin = t.min(axis=(1, 3))
    xmax = t.max(axis=(1, 3))
    span = xmax - xmin
    scale = jnp.where(span > 0, span / levels, 1.0)
    codes = jnp.clip(jnp.round((t - xmin[:, None, :, None])
                               / scale[:, None, :, None]), 0.0,
                     float(levels)).astype(jnp.uint8)
    gm, gn = m // bm, n // bn
    meta = jnp.zeros((gm, 2 * gn), jnp.float32)
    meta = meta.at[:, 0::2].set(xmin)
    meta = meta.at[:, 1::2].set(scale)
    return _untile(codes, m, n), meta


def topk_block_ref(x: jnp.ndarray, k_frac: float, block=(256, 512),
                   iters: int = 24):
    """Bit-exact mirror of kernels/topk_mask.py (same bisection)."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    k = jnp.float32(max(1, int(math.ceil(k_frac * bn))))
    t = _tile_view(x, bm, bn)
    mag = jnp.abs(t.astype(jnp.float32))
    hi = mag.max(axis=3, keepdims=True)
    lo = jnp.zeros_like(hi)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.float32), axis=3, keepdims=True)
        gt = cnt > k
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
    out = jnp.where(mag >= lo, t, jnp.zeros_like(t))
    return _untile(out, m, n)


def topk_exact_block_ref(x: jnp.ndarray, k_frac: float, block=(256, 512)):
    """EXACT per-row-per-tile TopK via sort — the semantic target the
    bisection approximates (used by property tests + convergence studies)."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    k = max(1, int(math.ceil(k_frac * bn)))
    t = _tile_view(x, bm, bn)
    mag = jnp.abs(t.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    out = jnp.where(mag >= thresh, t, jnp.zeros_like(t))
    return _untile(out, m, n)
