"""Pallas TPU kernel: fused min-max k-bit quantize->dequantize.

The boundary-compression hot path.  A naive jnp implementation makes three
HBM round-trips (min/max reduce, quantize, dequantize); this kernel does one:
each (bm, bn) VMEM tile computes its own min/max on the VPU, quantizes and
dequantizes in-register, and writes the result once.

TPU adaptation vs the paper (DESIGN.md §4): scales are PER-TILE rather than
per-tensor — strictly more accurate at equal wire cost (one fp32 pair per
tile), and it removes the global reduction dependency so tiles pipeline
freely through the MXU/VPU-adjacent VMEM.

Tile shapes are (8k, 128m)-aligned.  Validated in interpret mode on CPU
against kernels/ref.py; TPU is the deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qdq_kernel(x_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    xmin = jnp.min(x)
    xmax = jnp.max(x)
    span = xmax - xmin
    scale = jnp.where(span > 0, span / levels, 1.0)
    codes = jnp.clip(jnp.round((x - xmin) / scale), 0.0, float(levels))
    o_ref[...] = (codes * scale + xmin).astype(o_ref.dtype)


def _quantize_kernel(x_ref, codes_ref, meta_ref, *, levels: int):
    """Wire-format variant: uint8 codes + per-tile (min, scale) pair."""
    x = x_ref[...].astype(jnp.float32)
    xmin = jnp.min(x)
    xmax = jnp.max(x)
    span = xmax - xmin
    scale = jnp.where(span > 0, span / levels, 1.0)
    codes = jnp.clip(jnp.round((x - xmin) / scale), 0.0, float(levels))
    codes_ref[...] = codes.astype(jnp.uint8)
    meta_ref[0, 0] = xmin
    meta_ref[0, 1] = scale


def quant_dequant(x: jnp.ndarray, bits: int, *, block=(256, 256),
                  interpret: bool | None = None) -> jnp.ndarray:
    """x: (M, N), N % 128 == 0.  Returns C(x) with per-tile scales."""
    assert x.ndim == 2, x.shape
    m, n = x.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, (bm, bn))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_qdq_kernel, levels=(1 << bits) - 1),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)


def quantize_wire(x: jnp.ndarray, bits: int, *, block=(256, 256),
                  interpret: bool | None = None):
    """Returns (codes uint8 (M,N), meta fp32 (tiles_m, 2*tiles_n)) — the
    actual bytes a pipeline boundary sends (see core/pipeline.py)."""
    assert x.ndim == 2 and bits <= 8
    m, n = x.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, (bm, bn))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    gm, gn = m // bm, n // bn
    codes, meta = pl.pallas_call(
        functools.partial(_quantize_kernel, levels=(1 << bits) - 1),
        out_shape=(jax.ShapeDtypeStruct((m, n), jnp.uint8),
                   jax.ShapeDtypeStruct((gm, 2 * gn), jnp.float32)),
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=(pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 2), lambda i, j: (i, j))),
        interpret=interpret,
    )(x)
    return codes, meta


def dequantize_wire(codes, meta, dtype=jnp.float32, *, block=(256, 256)):
    """jnp inverse of quantize_wire (receiver side)."""
    m, n = codes.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    gm, gn = m // bm, n // bn
    mins = meta[:, 0::2]
    scales = meta[:, 1::2]
    c = codes.reshape(gm, bm, gn, bn).astype(dtype)
    out = (c * scales[:, None, :, None].astype(dtype)
           + mins[:, None, :, None].astype(dtype))
    return out.reshape(m, n)
