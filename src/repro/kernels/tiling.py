"""Tile-shape selection shared by the Pallas wire kernels and their
transport-layer dispatch (transport/codecs.py).

Two regimes:

* ``wire_tiling`` — the TILED kernels (q8 quantize_wire) block both dims,
  so the row block must respect the native f32 (8, 128) tile: the row
  block is the largest POWER-OF-TWO divisor of m capped at 256 (O(1),
  replacing an O(m) decrement scan that degraded to bm=1 on prime m), and
  shapes whose best row block would under-fill the 8-sublane tile get
  ``None`` — the dispatch falls back to the pure-jnp path rather than
  running 1-sublane tiles at 1/8th VPU utilization.

* ``full_row_block`` — the FULL-ROW kernels (q4 pair packing, TopK
  threshold) keep the whole feature dim resident per instance (per-row
  reductions / pair interleave need it), so any bm >= 1 is legal and the
  only cap is the VMEM budget; under-filled sublanes are tolerated since
  the lane dim dominates the layout for boundary-sized rows.
"""
from __future__ import annotations

from typing import Optional, Tuple

LANE_BLOCKS = (2048, 1024, 512, 256, 128)
MIN_SUBLANES = 8               # native f32 sublane tile
MAX_ROW_BLOCK = 256
VMEM_BUDGET = 4 * 1024 * 1024  # input bytes resident per kernel instance


def pow2_row_block(m: int, cap: int = MAX_ROW_BLOCK) -> int:
    """Largest power-of-two divisor of ``m``, capped at ``cap``."""
    return min(cap, m & -m) if m > 0 else 1


def lane_block(n: int) -> Optional[int]:
    for c in LANE_BLOCKS:
        if n % c == 0:
            return c
    return None


def wire_tiling(flat_shape) -> Optional[Tuple[int, int]]:
    """(bm, bn) for the tiled wire kernels, or None when no tiling fits
    (feature dim not a 128-multiple, or the row block would under-fill
    the native 8-sublane tile)."""
    m, n = flat_shape
    bn = lane_block(n)
    if bn is None:
        return None
    bm = pow2_row_block(m)
    if bm < MIN_SUBLANES:
        return None
    return bm, bn


def full_row_block(m: int, n: int, bytes_per_elem: int = 4,
                   budget: int = VMEM_BUDGET) -> int:
    """Row-block size for full-row kernels: the largest power-of-two
    divisor of ``m`` whose (bm, n) input block fits the VMEM budget."""
    bm = pow2_row_block(m)
    while bm > 1 and bm * n * bytes_per_elem > budget:
        bm //= 2
    return bm
