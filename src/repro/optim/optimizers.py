"""Pure-JAX optimizers (no optax offline): SGD+momentum+WD, AdamW,
cosine-annealing schedule.  Optimizer state is a pytree mirroring params;
moment dtype is configurable (bf16 moments keep the 400B MoE config inside
v5e HBM — see DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"                 # sgd | adamw
    lr: float = 0.01
    momentum: float = 0.9             # sgd
    beta1: float = 0.9                # adamw
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 5e-4
    grad_clip: float = 0.0            # 0 = off
    moment_dtype: Any = jnp.float32   # bf16 for the biggest configs
    # cosine schedule (paper: cosine annealing, T_max=200, lr0=0.01)
    schedule: str = "cosine"          # cosine | constant
    t_max: int = 200
    lr_min: float = 0.0
    warmup_steps: int = 0


def schedule_lr(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    lr = jnp.float32(cfg.lr)
    if cfg.schedule == "cosine":
        t = jnp.clip(step / max(cfg.t_max, 1), 0.0, 1.0)
        lr = cfg.lr_min + 0.5 * (cfg.lr - cfg.lr_min) * (1 + jnp.cos(math.pi * t))
    if cfg.warmup_steps:
        lr = lr * jnp.clip(step / cfg.warmup_steps, 0.0, 1.0)
    return lr


def init_opt_state(cfg: OptimizerConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "sgd":
        state["mu"] = jax.tree.map(zeros, params)
    elif cfg.kind == "adamw":
        state["mu"] = jax.tree.map(zeros, params)
        state["nu"] = jax.tree.map(zeros, params)
    else:
        raise ValueError(cfg.kind)
    return state


def _global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(lambda a, b: a + b, sq))


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)

    if cfg.grad_clip:
        gn = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    if cfg.kind == "sgd":
        def upd(p, g, m):
            gf = g.astype(jnp.float32)
            if cfg.weight_decay:
                gf = gf + cfg.weight_decay * p.astype(jnp.float32)
            m_new = cfg.momentum * m.astype(jnp.float32) + gf
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype)
        out = jax.tree.map(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "mu": new_mu}

    # adamw
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        p_new = (p.astype(jnp.float32)
                 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * p.astype(jnp.float32)))
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
            {"step": step,
             "mu": jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
             "nu": jax.tree.map(lambda o: o[2], out, is_leaf=is_t)})
