"""Train / eval steps wiring the paper's boundary compression into the
optimizer loop.

Two transports (see repro/transport/):

  * ``transport="simulated"`` — the paper's single-device boundary
    (core/boundary.py): the bw feedback buffers are updated inside
    backprop, so ``loss_fn`` takes them as a differentiated argument and
    the train step reads the update out of the gradient pytree.
  * ``transport="pipeline"``  — the REAL ``shard_map``/``ppermute``
    pipeline (transport/pipeline.py): packed payloads cross the wire in
    both directions; needs ``device_count >= policy.num_stages`` and a
    uniform per-cut policy (SPMD).  Feedback buffers (EF/EF21/EF-mixed/
    AQ-SGD) ride the pipeline scan carry: ``bstates`` is the
    ``init_feedback_state`` pytree ({"fw","bw"} of stage-stacked buffers)
    instead of the simulated per-boundary list; bw buffer updates are read
    out of the gradient w.r.t. ``bstates["bw"]``, mirroring the simulated
    path's cotangent trick.

Everything is jit-friendly and policy-static.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.parallel import ParallelSpec, from_legacy, warn_legacy
from repro.core.policy import CompressionPolicy, PolicyRules, resolve_policy
from repro.models import encdec, transformer
from repro.models.transformer import lm_loss
from repro.optim.optimizers import OptimizerConfig, apply_updates

# Sentinel distinguishing "caller passed the legacy kwarg" (deprecation
# shim -> ParallelSpec) from "default" on make_lm_train_step & friends.
_UNSET = object()

_LEGACY_DEFAULTS = {"dp": 1, "dp_codec": "none", "dp_feedback": "none",
                    "dp_k_frac": 0.1}


def _resolve_parallel(api: str, parallel, policy, transport: str, legacy):
    """Fold ``parallel=`` and the deprecated ``dp_*`` kwarg family into
    one ``(ParallelSpec, policy, transport)`` triple.

    Legacy kwargs (values ``_UNSET`` when not passed) construct the
    equivalent spec via :func:`repro.core.parallel.from_legacy` and warn
    once per call site; passing both families is an error.  A spec with
    ``stages > 1`` implies the pipeline transport; its stage wire
    (``spec.stage_policy()``) becomes the boundary policy unless the
    caller already supplied a compressing ``policy`` (conflict)."""
    explicit = tuple(sorted(k for k, v in legacy.items() if v is not _UNSET))
    if parallel is not None:
        if explicit:
            raise ValueError(
                f"{api}: both parallel= and the legacy kwarg(s) "
                f"{list(explicit)} were passed — drop the legacy kwargs")
        if not isinstance(parallel, ParallelSpec):
            raise TypeError(f"{api}: parallel= must be a ParallelSpec, "
                            f"got {type(parallel).__name__}")
        spec = parallel
    else:
        if explicit:
            warn_legacy(api, explicit)
        vals = {k: (legacy[k] if legacy[k] is not _UNSET else d)
                for k, d in _LEGACY_DEFAULTS.items()}
        spec = from_legacy(
            num_stages=(policy.num_stages if transport == "pipeline" else 1),
            **vals)
    for name in ("data", "stage", "tensor"):
        if spec.axis(name).is_rules:
            raise ValueError(
                f"{api}: the {name!r} axis codec is an unresolved rule "
                "spec — call ParallelSpec.resolved(wire_sizes, bandwidth) "
                "first (run_lm_experiment does this per epoch)")
    if parallel is not None and spec.stages > 1:
        if transport == "simulated":
            transport = "pipeline"
        sp = spec.stage_policy()
        if sp is not None:
            from repro.core.policy import NO_COMPRESSION
            if (policy.num_stages > 1 or policy.overrides
                    or policy.boundary != NO_COMPRESSION):
                raise ValueError(
                    f"{api}: both the stage axis wire "
                    f"({spec.stage.codec}+{spec.stage.feedback}) and a "
                    f"compressing policy= ({policy.name}) were given — "
                    "configure the stage boundary in ONE place")
            policy = sp
        elif policy.num_stages == 1:
            import dataclasses as _dc
            policy = _dc.replace(policy, num_stages=spec.stages)
        elif policy.num_stages != spec.stages:
            raise ValueError(
                f"{api}: policy.num_stages={policy.num_stages} != "
                f"parallel stage size {spec.stages}")
    return spec, policy, transport


def _resolve_rules(policy, boundary_feat):
    """Resolve a :class:`~repro.core.policy.PolicyRules` rule set into a
    concrete :class:`CompressionPolicy` at trace time.

    ``boundary_feat``: per-boundary tensor element count (one int for
    homogeneous cuts, or a sequence with one entry per cut).  Plain
    ``CompressionPolicy`` values pass through untouched, so a degenerate
    one-rule set reproduces a static-policy run bit-for-bit.
    """
    if isinstance(policy, PolicyRules):
        if boundary_feat is None:
            raise ValueError(
                "policy is a PolicyRules rule set — pass boundary_feat= "
                "(elements crossing each cut, e.g. seq_len * d_model for "
                "the LM) so rules can resolve to concrete codecs")
        return resolve_policy(policy, boundary_feat)
    return policy


def _uniform_boundary(policy: CompressionPolicy):
    """The single per-cut policy the SPMD pipeline runs at every cut."""
    from repro.core.policy import BoundaryPolicy
    if policy.num_boundaries == 0:
        return BoundaryPolicy()
    bps = [policy.at(i) for i in range(policy.num_boundaries)]
    if any(bp != bps[0] for bp in bps):
        raise ValueError("the SPMD pipeline transport needs the same "
                         "boundary policy at every cut (one program)")
    return bps[0]


def _pipeline_mesh(policy: CompressionPolicy, mesh, stage_axis: str):
    if mesh is not None:
        return mesh
    s = policy.num_stages
    if jax.device_count() < s:
        raise RuntimeError(
            f"pipeline transport needs >= {s} devices, have "
            f"{jax.device_count()} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={s} before jax init")
    return jax.make_mesh((s,), (stage_axis,))


def _tp_stage_fn(cfg, mesh, tp, tp_codec, tp_k_frac, tensor_axis):
    """Stage function + extra ``pipeline_apply`` kwargs for an optional
    tensor axis.  ``tp == 1`` returns the plain dense stage fn and no
    extra kwargs; ``tp > 1`` returns a TP-sharded stage fn (compressed
    all-gather / reduce-scatter per block, feedback-free) plus the
    ``tp_axis``/``tp_param_dims``/``seq_dim`` kwargs pipeline_apply needs
    to extend its shard_map specs over ``tensor_axis``."""
    if tp == 1:
        return transformer.stage_stack_fn(cfg), lambda stack: {}
    from repro.transport.tp_collectives import TPCollectives
    tpc = TPCollectives(mesh, tensor_axis, codec=tp_codec,
                        k_frac=tp_k_frac, feedback="none")
    tp_fn = transformer.tp_stage_stack_fn(cfg, tpc)

    def stage_fn(gp_stack, x):
        z = jnp.zeros((0,), x.dtype)
        return tp_fn(gp_stack, x, z, z)[0]

    def tp_kwargs(stack):
        return {"tp_axis": tensor_axis,
                "tp_param_dims": transformer.tp_param_dims(stack),
                "seq_dim": 1}

    return stage_fn, tp_kwargs


def _split_leading(tree, k: int):
    """Reshape every leaf ``(N, ...) -> (k, N/k, ...)``: the shard split
    shared by gradient accumulation (k chunks) and DP (k replica lanes)."""
    return jax.tree.map(
        lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), tree)


def _merge_leading(tree):
    """Inverse of :func:`_split_leading`."""
    return jax.tree.map(
        lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), tree)


def _split_states(bstates):
    fw = [s["fw"] for s in bstates]
    bw = [s["bw"] for s in bstates]
    return fw, bw


def _merge_states(fw, bw):
    return [{"fw": f, "bw": b} for f, b in zip(fw, bw)]


# ---------------------------------------------------------------------------
# LM train step (decoder-only + enc-dec)
# ---------------------------------------------------------------------------

def _resolve_grad_accum(grad_accum: int,
                        microbatches: Optional[int]) -> int:
    """``microbatches=`` is the deprecated name of the grad-accumulation
    knob (it collided with the pipeline's GPipe microbatch count)."""
    if microbatches is None:
        return grad_accum
    if grad_accum != 1:
        raise ValueError(
            f"both grad_accum={grad_accum} and its deprecated alias "
            f"microbatches={microbatches} were passed — drop microbatches=")
    warnings.warn(
        "microbatches= is deprecated (it means gradient accumulation, not "
        "pipeline microbatches): pass grad_accum= instead, and "
        "pipeline_microbatches= for the GPipe microbatch count",
        DeprecationWarning, stacklevel=3)
    return microbatches


def make_lm_train_step(cfg, policy: CompressionPolicy,
                       opt: OptimizerConfig, aux_weight: float = 0.01,
                       remat: bool = True, donate: bool = True,
                       jit: bool = True, grad_accum: int = 1,
                       microbatches: Optional[int] = None,
                       transport: str = "simulated", mesh=None,
                       stage_axis: str = "stage",
                       pipeline_microbatches: Optional[int] = None,
                       schedule: str = "gpipe", virtual_stages: int = 1,
                       dp=_UNSET, dp_codec=_UNSET,
                       dp_feedback=_UNSET, dp_k_frac=_UNSET,
                       data_axis: str = "data", boundary_feat=None,
                       parallel: Optional[ParallelSpec] = None,
                       tensor_axis: str = "tensor"):
    """Returns jit'd ``step(params, opt_state, bstates, batch, ids)
    -> (params, opt_state, bstates, metrics)``.

    batch: {"tokens": (B,S)} (+ modality stubs); next-token LM loss.
    ``grad_accum > 1``: gradient accumulation — the global batch is split
    along B and scanned, bounding per-device activation memory at
    B/grad_accum (feedback buffers and ids are sliced alongside, so the
    paper's per-example semantics are preserved).  ``microbatches=`` is a
    deprecated alias for ``grad_accum=``.

    ``transport="pipeline"`` trains through the real ``ppermute`` path:
    embed + loss run replicated, the layer stack runs as a compressed
    pipeline over ``mesh``'s ``stage_axis`` under ``schedule``
    (gpipe | 1f1b | interleaved; ``virtual_stages`` slices per device for
    interleaved; ``pipeline_microbatches`` defaults to the stage count).

    ``dp > 1`` adds a data-parallel dimension with a COMPRESSED gradient
    all-reduce (transport/collectives.py): the global batch splits into
    ``dp`` contiguous shards, per-replica gradients cross the ``data``
    mesh axis packed by ``dp_codec`` (none/q8/q4/topk at ``dp_k_frac``),
    optionally error-compensated per replica (``dp_feedback``:
    ef | ef21).  The step signature gains a DP-state argument:
    ``step(params, opt_state, bstates, batch, ids, dp_state)
    -> (params, opt_state, bstates, dp_state, metrics)`` with ``dp_state``
    from :func:`repro.transport.collectives.init_dp_state`.  On the
    simulated transport the replicas are ``vmap`` lanes around the paper's
    boundary (``grad_accum`` composes per lane — accumulate locally,
    reduce once); on the pipeline transport the mesh is the 2D
    ``(data, stages)`` grid and the reduced tree is the pipelined layer
    stack (embed/head/norm grads stay exact: they run replicated).

    ``parallel=`` (a :class:`~repro.core.parallel.ParallelSpec`) is the
    ONE argument that now configures all three axes — sizes and wires for
    ``data`` (the compressed gradient all-reduce), ``stage`` (the
    pipeline boundary; ``stages > 1`` implies the pipeline transport) and
    ``tensor`` (the compressed TP collectives,
    transport/tp_collectives.py).  The ``dp``/``dp_codec``/
    ``dp_feedback``/``dp_k_frac`` kwargs are a DEPRECATED alias family
    (they construct the equivalent spec and warn with
    ``ParallelDeprecationWarning``); passing both families is an error.

    ``tp > 1`` shards the dense-family layer stack over the tensor axis
    (Megatron-SP: sequence-sharded residual, head/d_ff-sharded weights)
    with the all-gather/reduce-scatter packed by the tensor wire codec.
    The step gains a trailing ``tp_state`` argument (from
    :func:`repro.transport.tp_collectives.init_tp_state`) and returns it
    updated: ``step(params, opt_state, bstates, batch, ids[, dp_state],
    tp_state)``.
    """
    mod = encdec if cfg.enc_dec else transformer
    policy = _resolve_rules(policy, boundary_feat)
    grad_accum = _resolve_grad_accum(grad_accum, microbatches)
    spec, policy, transport = _resolve_parallel(
        "make_lm_train_step", parallel, policy, transport,
        {"dp": dp, "dp_codec": dp_codec, "dp_feedback": dp_feedback,
         "dp_k_frac": dp_k_frac})
    dp, tp = spec.dp, spec.tp
    d_ax, t_ax = spec.data, spec.tensor
    dp_codec, dp_feedback, dp_k_frac = d_ax.codec, d_ax.feedback, d_ax.k_frac
    if transport == "pipeline":
        if grad_accum > 1:
            raise NotImplementedError(
                "grad_accum > 1 is not supported with transport='pipeline' "
                "— bound activation memory with pipeline_microbatches (the "
                "1f1b schedule keeps the stash at the boundary tensors)")
        return _make_pipeline_lm_train_step(
            cfg, policy, opt, mesh=mesh, stage_axis=stage_axis,
            microbatches=pipeline_microbatches, jit=jit,
            schedule=schedule, virtual_stages=virtual_stages,
            dp=dp, dp_codec=dp_codec, dp_feedback=dp_feedback,
            dp_k_frac=dp_k_frac, data_axis=data_axis, tp=tp,
            tp_codec=t_ax.codec, tp_k_frac=t_ax.k_frac,
            tp_feedback=t_ax.feedback, tensor_axis=tensor_axis)
    if transport != "simulated":
        raise ValueError(f"unknown transport {transport!r}")
    if tp > 1:
        if grad_accum > 1:
            raise NotImplementedError("grad_accum > 1 + tensor parallelism")
        return _make_tp_lm_train_step(
            cfg, policy, opt, mesh=mesh, jit=jit, dp=dp, tp=tp,
            dp_codec=dp_codec, dp_feedback=dp_feedback,
            dp_k_frac=dp_k_frac, data_axis=data_axis,
            tp_codec=t_ax.codec, tp_feedback=t_ax.feedback,
            tp_k_frac=t_ax.k_frac, tensor_axis=tensor_axis)

    def loss_fn(params, bw_bufs, fw_bufs, batch, ids):
        bstates = _merge_states(fw_bufs, bw_bufs)
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        # chunked loss from hidden states: (B,S,V) logits never
        # materialized (see transformer.hidden_lm_loss) — both stacks
        x, aux, new_fw = mod.forward_hidden(
            params, batch, cfg, policy, bstates or None, ids,
            remat=remat)
        loss = transformer.hidden_lm_loss(params, x, labels, cfg, mask)
        total = loss + aux_weight * aux
        return total, (loss, aux, new_fw)

    def compute_grads(params, bw_bufs, fw_bufs, batch, ids):
        """One replica's (grads, new_fw, new_bw, metrics) over its batch
        shard; ``grad_accum`` scans within the shard, so accumulation
        composes with the DP reduce (accumulate locally, reduce once)."""
        if grad_accum == 1:
            (total, (loss, aux, new_fw)), (grads, new_bw) = \
                jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
                    params, bw_bufs, fw_bufs, batch, ids)
            return grads, new_fw, new_bw, {"loss": loss, "aux": aux,
                                           "total": total}
        mb = grad_accum
        split = lambda t: _split_leading(t, mb)
        xs = (split(batch), split(ids), split(fw_bufs), split(bw_bufs))
        grad0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, xs_i):
            gacc, loss_a, aux_a = carry
            b_i, id_i, fw_i, bw_i = xs_i
            (_, (loss, aux, new_fw)), (g, new_bw) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(
                    params, bw_i, fw_i, b_i, id_i)
            gacc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32), gacc, g)
            return (gacc, loss_a + loss, aux_a + aux), (new_fw, new_bw)

        (gacc, loss_s, aux_s), (new_fw_s, new_bw_s) = jax.lax.scan(
            body, (grad0, jnp.float32(0.0), jnp.float32(0.0)), xs)
        grads = jax.tree.map(lambda g: (g / mb).astype(jnp.bfloat16), gacc)
        new_fw = [_merge_leading(b) for b in new_fw_s]
        new_bw = [_merge_leading(b) for b in new_bw_s]
        metrics = {"loss": loss_s / mb, "aux": aux_s / mb,
                   "total": (loss_s + aux_weight * aux_s) / mb}
        return grads, new_fw, new_bw, metrics

    if grad_accum > 1 and policy.num_boundaries and any(
            policy.at(i).feedback == "aqsgd"
            for i in range(policy.num_boundaries)):
        raise NotImplementedError("aqsgd + gradient accumulation")

    def step(params, opt_state, bstates, batch, ids):
        fw_bufs, bw_bufs = _split_states(bstates)
        grads, new_fw, new_bw, metrics = compute_grads(
            params, bw_bufs, fw_bufs, batch, ids)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        new_states = _merge_states(new_fw if new_fw else fw_bufs, new_bw)
        return params, opt_state, new_states, metrics

    if dp > 1:
        step = _make_dp_simulated_step(policy, opt, compute_grads, dp,
                                       dp_codec, dp_feedback, dp_k_frac,
                                       data_axis)

    if not jit:
        return step
    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


def _make_dp_simulated_step(policy, opt, compute_grads, dp, dp_codec,
                            dp_feedback, dp_k_frac, data_axis):
    """Data-parallel wrapper around the simulated-boundary gradient
    computation: ``dp`` ``vmap`` lanes (one per contiguous batch shard),
    then one compressed all-reduce of the per-lane gradients over the
    ``data`` mesh axis.  Global feedback buffers split by batch shard;
    AQ-SGD's dataset-indexed ``(num_samples, *feat)`` buffer splits BY
    EXAMPLE ID (lane r owns rows ``[r*ns/dp, (r+1)*ns/dp)`` and addresses
    them with localized ids — :func:`repro.core.feedback.shard_ids`), so
    the per-example compensation never crosses lanes."""
    from repro.core.feedback import shard_ids
    from repro.launch.mesh import make_data_mesh
    from repro.transport.collectives import make_grad_all_reduce
    has_aqsgd = policy.num_boundaries and any(
        policy.at(i).feedback == "aqsgd"
        for i in range(policy.num_boundaries))
    mesh = make_data_mesh(dp, data_axis=data_axis)
    reduce_fn = make_grad_all_reduce(mesh, data_axis, dp_codec,
                                     k_frac=dp_k_frac,
                                     feedback=dp_feedback, average=True)

    def step_dp(params, opt_state, bstates, batch, ids, dp_state):
        fw_bufs, bw_bufs = _split_states(bstates)
        ids_sh = _split_leading(ids, dp)
        if has_aqsgd:
            # the (num_samples, *feat) resid's _split_leading IS the
            # id-shard: localize each lane's ids to its shard rows
            ns = next(fw_bufs[i].resid.shape[0]
                      for i in range(policy.num_boundaries)
                      if policy.at(i).feedback == "aqsgd")
            ids_sh = jax.vmap(
                lambda i, r: shard_ids(i, r, ns, dp))(
                    ids_sh, jnp.arange(dp, dtype=ids.dtype))
        g_dp, new_fw_dp, new_bw_dp, met = jax.vmap(
            compute_grads, in_axes=(None, 0, 0, 0, 0))(
                params, _split_leading(bw_bufs, dp),
                _split_leading(fw_bufs, dp), _split_leading(batch, dp),
                ids_sh)
        grads, new_dp_state = reduce_fn(g_dp, dp_state)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        new_fw = [_merge_leading(b) for b in new_fw_dp]
        new_bw = [_merge_leading(b) for b in new_bw_dp]
        new_states = _merge_states(new_fw if new_fw else fw_bufs, new_bw)
        metrics = jax.tree.map(jnp.mean, met)
        return params, opt_state, new_states, new_dp_state, metrics

    return step_dp


def _make_tp_lm_train_step(cfg, policy: CompressionPolicy,
                           opt: OptimizerConfig, *, mesh=None,
                           jit: bool = True, dp: int = 1, tp: int = 2,
                           dp_codec: str = "none",
                           dp_feedback: str = "none",
                           dp_k_frac: float = 0.1,
                           data_axis: str = "data",
                           tp_codec: str = "none",
                           tp_feedback: str = "none",
                           tp_k_frac: float = 0.1,
                           tensor_axis: str = "tensor"):
    """LM training with the dense layer stack sharded over the tensor
    ring (transport/tp_collectives.py), optionally composed with the
    compressed DP gradient all-reduce on a ``(data, 1, tensor)`` mesh.

    Embed + chunked loss run OUTSIDE the shard_map on the global batch
    (exact gradients, like the dp-pipeline path); the stack rides in as a
    separately-differentiated argument (dp-stacked broadcast when
    ``dp > 1``), so its gradient comes back per replica for the
    compressed reduce with no hidden cross-replica psum.  Step signature
    gains a trailing ``tp_state``:
    ``step(params, opt_state, bstates, batch, ids[, dp_state], tp_state)``.
    """
    if cfg.enc_dec:
        raise NotImplementedError("tensor parallelism: decoder-only archs")
    if policy.num_boundaries:
        raise NotImplementedError(
            "simulated boundary cuts + tensor parallelism: run the stage "
            "wire through the pipeline transport (3D mesh) instead")
    from repro.launch.mesh import make_3d_mesh, make_tensor_mesh
    from repro.transport.collectives import make_grad_all_reduce
    from repro.transport.tp_collectives import TPCollectives, tp_apply
    if mesh is None:
        mesh = (make_tensor_mesh(tp, tensor_axis=tensor_axis) if dp == 1
                else make_3d_mesh(dp, 1, tp, data_axis=data_axis,
                                  tensor_axis=tensor_axis))
    tpc = TPCollectives(mesh, tensor_axis, codec=tp_codec, k_frac=tp_k_frac,
                        feedback=tp_feedback)
    stage_fn = transformer.tp_stage_stack_fn(cfg, tpc)
    sites = transformer.tp_sites(cfg)

    def forward(params, stack_in, batch, tp_state):
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        x = transformer._embed_input(params, batch, cfg)
        # param_dims from the UNSTACKED stack: tp_apply itself accounts
        # for the leading dp replica dim via batch_axis
        y, new_tp = tp_apply(
            stage_fn, stack_in, x, tpc,
            param_dims=transformer.tp_param_dims(params["layers"]),
            state=tp_state,
            batch_axis=(data_axis if dp > 1 else None), sites=sites)
        loss = transformer.hidden_lm_loss(params, y, labels, cfg, mask)
        return loss, new_tp

    def step_tp(params, opt_state, bstates, batch, ids, tp_state):
        (loss, new_tp), (g_params, g_stack) = jax.value_and_grad(
            lambda p, s: forward(p, s, batch, tp_state),
            argnums=(0, 1), has_aux=True)(params, params["layers"])
        grads = dict(g_params)
        grads["layers"] = g_stack
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "aux": jnp.float32(0.0), "total": loss}
        return params, opt_state, bstates, new_tp, metrics

    def step_dp_tp(params, opt_state, bstates, batch, ids, dp_state,
                   tp_state):
        stack_dp = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (dp, *a.shape)),
            params["layers"])
        (loss, new_tp), (g_params, g_stack_dp) = jax.value_and_grad(
            lambda p, s: forward(p, s, batch, tp_state),
            argnums=(0, 1), has_aux=True)(params, stack_dp)
        reduce_fn = make_grad_all_reduce(
            mesh, data_axis, dp_codec, k_frac=dp_k_frac,
            feedback=dp_feedback, average=False, tp_axis=tensor_axis,
            tp_dims=transformer.tp_param_dims(g_stack_dp))
        g_stack, new_dp_state = reduce_fn(g_stack_dp, dp_state)
        grads = dict(g_params)
        grads["layers"] = g_stack
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "aux": jnp.float32(0.0), "total": loss}
        return (params, opt_state, bstates, new_dp_state, new_tp, metrics)

    step = step_dp_tp if dp > 1 else step_tp
    return jax.jit(step) if jit else step


def _make_pipeline_lm_train_step(cfg, policy: CompressionPolicy,
                                 opt: OptimizerConfig, *, mesh=None,
                                 stage_axis: str = "stage",
                                 microbatches: Optional[int] = None,
                                 jit: bool = True, schedule: str = "gpipe",
                                 virtual_stages: int = 1, dp: int = 1,
                                 dp_codec: str = "none",
                                 dp_feedback: str = "none",
                                 dp_k_frac: float = 0.1,
                                 data_axis: str = "data", tp: int = 1,
                                 tp_codec: str = "none",
                                 tp_feedback: str = "none",
                                 tp_k_frac: float = 0.1,
                                 tensor_axis: str = "tensor"):
    """LM training through the real compressed ``ppermute`` pipeline.

    Same ``step(params, opt_state, bstates, batch, ids)`` signature as the
    simulated path.  With a feedback-free policy ``bstates`` passes through
    (``[]``); with EF/EF21/EF-mixed/AQ-SGD it is the
    :func:`repro.transport.pipeline.init_feedback_state` pytree and the
    step returns the updated buffers (bw side read from the gradient).
    With the interleaved schedule the layer stack splits into
    ``num_stages * virtual_stages`` logical slices (round-robin per
    device).  MoE aux losses are not threaded through the pipeline
    (stage_fn is single-tensor); fine for the dense smoke archs this path
    targets.
    """
    if cfg.enc_dec:
        raise NotImplementedError("pipeline transport: decoder-only archs")
    from repro.transport.pipeline import pipeline_apply
    bp = _uniform_boundary(policy)
    s_stages = policy.num_stages
    needs_state = bp.needs_fw_buffer or bp.needs_bw_buffer
    if tp > 1 and tp_feedback != "none":
        raise NotImplementedError(
            "pipeline + tensor parallelism: feedback-free tensor wires only "
            "(EF/EF21 state does not thread through pipeline_apply yet)")
    if dp > 1:
        from repro.launch.mesh import make_3d_mesh, make_dp_pipeline_mesh
        if mesh is None:
            mesh = (make_dp_pipeline_mesh(dp, s_stages, data_axis=data_axis,
                                          stage_axis=stage_axis) if tp == 1
                    else make_3d_mesh(dp, s_stages, tp, data_axis=data_axis,
                                      stage_axis=stage_axis,
                                      tensor_axis=tensor_axis))
        return _make_dp_pipeline_lm_train_step(
            cfg, bp, opt, mesh=mesh, stage_axis=stage_axis,
            data_axis=data_axis, microbatches=microbatches, jit=jit,
            schedule=schedule, virtual_stages=virtual_stages, dp=dp,
            dp_codec=dp_codec, dp_feedback=dp_feedback,
            dp_k_frac=dp_k_frac, s_stages=s_stages, tp=tp,
            tp_codec=tp_codec, tp_k_frac=tp_k_frac, tensor_axis=tensor_axis)
    if tp > 1:
        from repro.launch.mesh import make_3d_mesh
        if mesh is None:
            mesh = make_3d_mesh(1, s_stages, tp, data_axis=data_axis,
                                stage_axis=stage_axis,
                                tensor_axis=tensor_axis)
    else:
        mesh = _pipeline_mesh(policy, mesh, stage_axis)
    stage_fn, tp_kwargs = _tp_stage_fn(cfg, mesh, tp, tp_codec, tp_k_frac,
                                       tensor_axis)

    def forward(params, batch, fw_state, bw_state, ids):
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        x = transformer._embed_input(params, batch, cfg)
        stack = transformer.stack_layer_stages(params,
                                               s_stages * virtual_stages)
        new_fw = None
        if needs_state:
            x, new_fw = pipeline_apply(
                stage_fn, stack, x, mesh, stage_axis,
                policy=bp, microbatches=microbatches, schedule=schedule,
                virtual_stages=virtual_stages,
                fw_state=fw_state, bw_state=bw_state, ids=ids,
                **tp_kwargs(stack))
        else:
            x = pipeline_apply(stage_fn, stack, x, mesh, stage_axis,
                               policy=bp,
                               microbatches=microbatches, schedule=schedule,
                               virtual_stages=virtual_stages,
                               **tp_kwargs(stack))
        loss = transformer.hidden_lm_loss(params, x, labels, cfg, mask)
        return loss, new_fw

    def step(params, opt_state, bstates, batch, ids):
        loss, grads = jax.value_and_grad(
            lambda p: forward(p, batch, None, None, ids)[0])(params)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "aux": jnp.float32(0.0), "total": loss}
        return params, opt_state, bstates, metrics

    def step_feedback(params, opt_state, bstates, batch, ids):
        def loss_fn(params, bw_state):
            return forward(params, batch, bstates["fw"], bw_state, ids)
        (loss, new_fw), (grads, new_bw) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(params, bstates["bw"])
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "aux": jnp.float32(0.0), "total": loss}
        return params, opt_state, {"fw": new_fw, "bw": new_bw}, metrics

    step = step_feedback if needs_state else step
    return jax.jit(step) if jit else step


def _make_dp_pipeline_lm_train_step(cfg, bp, opt: OptimizerConfig, *, mesh,
                                    stage_axis: str, data_axis: str,
                                    microbatches: Optional[int],
                                    jit: bool, schedule: str,
                                    virtual_stages: int, dp: int,
                                    dp_codec: str, dp_feedback: str,
                                    dp_k_frac: float, s_stages: int,
                                    tp: int = 1, tp_codec: str = "none",
                                    tp_k_frac: float = 0.1,
                                    tensor_axis: str = "tensor"):
    """LM training on the 2D ``(data, stages)`` mesh: every replica row
    pipelines its contiguous batch shard through the compressed
    ``ppermute`` wire, and the per-replica LAYER-STACK gradients cross the
    ``data`` axis through the compressed all-reduce
    (transport/collectives.py).  The stack rides into the loss as a
    dp-stacked broadcast copy, so its gradient comes back per replica with
    no hidden ``psum``; embed/head/norm run replicated on the global batch
    and keep exact gradients.  Step signature:
    ``step(params, opt_state, bstates, batch, ids, dp_state)``.

    Boundary feedback composes with dp: ``bstates`` is the
    :func:`repro.transport.pipeline.init_feedback_state` pytree built with
    ``dp=dp`` (leading replica dim, sharded over the ``data`` axis — each
    replica row compensates its own batch shard; AQ-SGD id-shards), and
    the bw side comes back as the gradient w.r.t. ``bstates["bw"]``,
    exactly like the solo pipeline step.
    """
    from repro.transport.pipeline import pipeline_apply
    from repro.transport.collectives import make_grad_all_reduce
    # shard the reduce over the stage axis too: each stage column rings
    # only its own slice of the stack gradient (which pipeline_apply
    # already leaves P(stage)-sharded — no reshard gather).  With tp > 1
    # the reduce is additionally tensor-sharded per leaf, so it is built
    # at trace time in _finish (the tp_dims tree needs the grad pytree).
    reduce_fn = None
    if tp == 1:
        reduce_fn = make_grad_all_reduce(
            mesh, data_axis, dp_codec, k_frac=dp_k_frac,
            feedback=dp_feedback, average=False, shard_axis=stage_axis)
    stage_fn, tp_kwargs = _tp_stage_fn(cfg, mesh, tp, tp_codec, tp_k_frac,
                                       tensor_axis)
    n_slices = s_stages * virtual_stages
    needs_state = bp.needs_fw_buffer or bp.needs_bw_buffer

    def forward_dp(params, stack_dp, batch, ids, fw_state, bw_state):
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        x = transformer._embed_input(params, batch, cfg)
        new_fw = None
        if needs_state:
            x, new_fw = pipeline_apply(
                stage_fn, stack_dp, x, mesh,
                stage_axis, policy=bp, microbatches=microbatches,
                schedule=schedule, virtual_stages=virtual_stages,
                dp_axis=data_axis, fw_state=fw_state, bw_state=bw_state,
                ids=ids, **tp_kwargs(stack_dp))
        else:
            x = pipeline_apply(
                stage_fn, stack_dp, x, mesh,
                stage_axis, policy=bp, microbatches=microbatches,
                schedule=schedule, virtual_stages=virtual_stages,
                dp_axis=data_axis, **tp_kwargs(stack_dp))
        loss = transformer.hidden_lm_loss(params, x, labels, cfg, mask)
        return loss, new_fw

    def _stack_dp(params):
        stack = transformer.stack_layer_stages(params, n_slices)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (dp, *a.shape)), stack)

    def _finish(params, opt_state, g_params, g_stack_dp, dp_state, loss):
        rf = reduce_fn
        if rf is None:
            rf = make_grad_all_reduce(
                mesh, data_axis, dp_codec, k_frac=dp_k_frac,
                feedback=dp_feedback, average=False, shard_axis=stage_axis,
                tp_axis=tensor_axis,
                tp_dims=transformer.tp_param_dims(g_stack_dp))
        g_stack, new_dp_state = rf(g_stack_dp, dp_state)
        grads = dict(g_params)
        grads["layers"] = jax.tree.map(
            lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]),
            g_stack)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "aux": jnp.float32(0.0), "total": loss}
        return params, opt_state, new_dp_state, metrics

    def step(params, opt_state, bstates, batch, ids, dp_state):
        loss, (g_params, g_stack_dp) = jax.value_and_grad(
            lambda p, s: forward_dp(p, s, batch, ids, None, None)[0],
            argnums=(0, 1))(params, _stack_dp(params))
        params, opt_state, new_dp_state, metrics = _finish(
            params, opt_state, g_params, g_stack_dp, dp_state, loss)
        return params, opt_state, bstates, new_dp_state, metrics

    def step_feedback(params, opt_state, bstates, batch, ids, dp_state):
        def loss_fn(params, stack_dp, bw_state):
            return forward_dp(params, stack_dp, batch, ids,
                              bstates["fw"], bw_state)
        (loss, new_fw), (g_params, g_stack_dp, new_bw) = jax.value_and_grad(
            loss_fn, argnums=(0, 1, 2), has_aux=True)(
                params, _stack_dp(params), bstates["bw"])
        params, opt_state, new_dp_state, metrics = _finish(
            params, opt_state, g_params, g_stack_dp, dp_state, loss)
        return (params, opt_state, {"fw": new_fw, "bw": new_bw},
                new_dp_state, metrics)

    step = step_feedback if needs_state else step
    return jax.jit(step) if jit else step


def make_lm_eval_step(cfg, policy: CompressionPolicy, compress: bool):
    mod = encdec if cfg.enc_dec else transformer

    @jax.jit
    def step(params, batch):
        logits = mod.forward_eval(params, batch, cfg, policy,
                                  compress=compress)
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return lm_loss(logits, labels, mask)

    return step


# ---------------------------------------------------------------------------
# Image-classification train step (paper's ResNet18/CIFAR-10 experiments)
# ---------------------------------------------------------------------------

def xent_loss(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def make_cnn_train_step(policy: CompressionPolicy, opt: OptimizerConfig,
                        transport: str = "simulated", mesh=None,
                        stage_axis: str = "stage",
                        pipeline_microbatches: Optional[int] = None,
                        schedule: str = "gpipe", virtual_stages: int = 1,
                        boundary_feat=None):
    from repro.models import cnn

    policy = _resolve_rules(policy, boundary_feat)
    if transport == "pipeline":
        return _make_pipeline_cnn_train_step(
            policy, opt, mesh=mesh, stage_axis=stage_axis,
            microbatches=pipeline_microbatches, schedule=schedule,
            virtual_stages=virtual_stages)
    if transport != "simulated":
        raise ValueError(f"unknown transport {transport!r}")

    def loss_fn(params, bw_bufs, fw_bufs, images, labels, ids):
        bstates = _merge_states(fw_bufs, bw_bufs)
        logits, new_fw = cnn.forward_train(params, images, policy,
                                           bstates or None, ids)
        return xent_loss(logits, labels), (logits, new_fw)

    @jax.jit
    def step(params, opt_state, bstates, images, labels, ids):
        fw_bufs, bw_bufs = _split_states(bstates)
        (loss, (logits, new_fw)), (grads, new_bw) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                params, bw_bufs, fw_bufs, images, labels, ids)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        acc = (logits.argmax(-1) == labels).mean()
        new_states = _merge_states(new_fw if new_fw else fw_bufs, new_bw)
        return params, opt_state, new_states, {"loss": loss, "acc": acc}

    return step


def _make_pipeline_cnn_train_step(policy: CompressionPolicy,
                                  opt: OptimizerConfig, *, mesh=None,
                                  stage_axis: str = "stage",
                                  microbatches: Optional[int] = None,
                                  schedule: str = "gpipe",
                                  virtual_stages: int = 1):
    """CNN training through the real compressed ``ppermute`` pipeline.

    Uses the homogeneous-stage CNN (models/cnn.py ``init_pipeline_params``
    — with the interleaved schedule, built with ``S * virtual_stages``
    logical stages); stem + head run replicated, the residual stages
    pipeline over the mesh with packed fw/bw payloads under ``schedule``.
    Signature matches the simulated step; with a feedback policy
    ``bstates`` is the ``init_feedback_state`` pytree and comes back
    updated (bw side via the gradient), otherwise it passes through
    unchanged.
    """
    from repro.models import cnn
    from repro.transport.pipeline import pipeline_apply
    bp = _uniform_boundary(policy)
    mesh = _pipeline_mesh(policy, mesh, stage_axis)
    needs_state = bp.needs_fw_buffer or bp.needs_bw_buffer

    def forward(params, images, labels, fw_state, bw_state, ids):
        x = cnn.pipeline_stem(params, images)
        new_fw = None
        if needs_state:
            x, new_fw = pipeline_apply(
                cnn.pipeline_stage_apply, params["stages"], x, mesh,
                stage_axis, policy=bp, microbatches=microbatches,
                schedule=schedule, virtual_stages=virtual_stages,
                fw_state=fw_state, bw_state=bw_state, ids=ids)
        else:
            x = pipeline_apply(cnn.pipeline_stage_apply, params["stages"],
                               x, mesh, stage_axis, policy=bp,
                               microbatches=microbatches, schedule=schedule,
                               virtual_stages=virtual_stages)
        logits = cnn.pipeline_head(params, x)
        return xent_loss(logits, labels), (logits, new_fw)

    @jax.jit
    def step(params, opt_state, bstates, images, labels, ids):
        (loss, (logits, _)), grads = jax.value_and_grad(
            forward, has_aux=True)(params, images, labels, None, None, ids)
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        acc = (logits.argmax(-1) == labels).mean()
        return params, opt_state, bstates, {"loss": loss, "acc": acc}

    @jax.jit
    def step_feedback(params, opt_state, bstates, images, labels, ids):
        def loss_fn(params, bw_state):
            return forward(params, images, labels, bstates["fw"],
                           bw_state, ids)
        (loss, (logits, new_fw)), (grads, new_bw) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(params, bstates["bw"])
        params, opt_state = apply_updates(opt, params, grads, opt_state)
        acc = (logits.argmax(-1) == labels).mean()
        return (params, opt_state, {"fw": new_fw, "bw": new_bw},
                {"loss": loss, "acc": acc})

    return step_feedback if needs_state else step


def make_cnn_eval_step(policy: CompressionPolicy, compress: bool,
                       transport: str = "simulated"):
    from repro.models import cnn

    fwd = (cnn.pipeline_forward_eval if transport == "pipeline"
           else cnn.forward_eval)

    @jax.jit
    def step(params, images, labels):
        logits = fwd(params, images, policy, compress=compress)
        return (logits.argmax(-1) == labels).mean(), xent_loss(logits, labels)

    return step
