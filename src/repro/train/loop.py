"""Experiment training loops reproducing the paper's protocol.

``run_cnn_experiment`` mirrors Sec. 3.1 (ResNet/CIFAR-10): train with a
compression policy, evaluate test accuracy BOTH with compression on and off
(the paper's two right columns), support warm-starting from uncompressed
baseline weights after N epochs ("warmup 20" rows).

``run_lm_experiment`` mirrors Sec. 3.2 (GPT-2/Wikitext fine-tuning): first
"pretrain" a tiny LM without compression, then fine-tune with TopK
compression (index-reuse vs separate) and report eval loss / perplexity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.parallel import ParallelSpec, from_legacy, warn_legacy
from repro.core.policy import (CompressionPolicy, NO_POLICY, PolicyRules,
                               resolve_policy)
from repro.data.synthetic import ImageClassData, LMData
from repro.models import cnn, transformer
from repro.models.config import ModelConfig
from repro.obs import trace
from repro.obs.probes import boundary_bandwidth
from repro.optim.optimizers import OptimizerConfig, init_opt_state
from repro.train.steps import (_LEGACY_DEFAULTS, _UNSET, _resolve_parallel,
                               make_cnn_eval_step, make_cnn_train_step,
                               make_lm_eval_step, make_lm_train_step)


@dataclasses.dataclass
class ExperimentResult:
    name: str
    acc_off: float = 0.0           # eval with compression OFF
    acc_on: float = 0.0            # eval with compression ON
    loss_on: float = 0.0
    loss_off: float = 0.0
    train_curve: List[float] = dataclasses.field(default_factory=list)
    seconds: float = 0.0
    # resolved policy name per epoch — flat unless a bandwidth probe
    # re-resolved PolicyRules mid-run (the closed loop's audit trail)
    policy_curve: List[str] = dataclasses.field(default_factory=list)

    def row(self) -> str:
        return (f"{self.name:32s}  off={self.acc_off:6.2f}%  "
                f"on={self.acc_on:6.2f}%")


def _cnn_eval(params, data, policy, compress, batch=100,
              transport="simulated") -> tuple:
    step = make_cnn_eval_step(policy, compress, transport=transport)
    accs, losses = [], []
    for x, y, _ in data.test_batches(batch):
        a, l = step(params, jnp.asarray(x), jnp.asarray(y))
        accs.append(float(a))
        losses.append(float(l))
    return 100.0 * float(np.mean(accs)), float(np.mean(losses))


def run_cnn_experiment(policy: CompressionPolicy, *, epochs: int = 8,
                       batch: int = 100, width: int = 16,
                       data: Optional[ImageClassData] = None,
                       warmup_params=None, name: str = "",
                       opt: Optional[OptimizerConfig] = None,
                       seed: int = 0, transport: str = "simulated",
                       mesh=None, stage_axis: str = "stage",
                       pipeline_microbatches: Optional[int] = None,
                       schedule: str = "gpipe", virtual_stages: int = 1
                       ) -> ExperimentResult:
    """Train the ResNet with boundary compression; paper protocol.

    ``warmup_params``: start from these (uncompressed-baseline) weights —
    the paper's "warmup N" rows.

    ``transport="pipeline"`` trains the homogeneous-stage CNN variant
    through the REAL compressed ``shard_map``/``ppermute`` pipeline
    (needs ``device_count >= policy.num_stages``; same boundary policy at
    every cut; EF/EF21/EF-mixed/AQ-SGD feedback buffers ride the pipeline
    scan carry) under ``schedule`` (gpipe | 1f1b | interleaved — the
    latter builds ``num_stages * virtual_stages`` logical stage slices).
    """
    data = data or ImageClassData()
    if isinstance(policy, PolicyRules):
        # CNN cuts are heterogeneous: resolve each rule against the real
        # per-boundary element count (pipeline stages are homogeneous)
        sizes = (data.image * data.image * width
                 if transport == "pipeline" else
                 [int(np.prod(s)) for s in
                  cnn.boundary_shapes(width, data.image)])
        policy = resolve_policy(policy, sizes)
    opt = opt or OptimizerConfig(kind="sgd", lr=0.02, momentum=0.9,
                                 weight_decay=5e-4, schedule="cosine",
                                 t_max=epochs * (data.num_train // batch))
    if transport == "pipeline":
        if warmup_params is not None:
            raise ValueError("warmup_params: homogeneous pipeline CNN has "
                             "a different param structure")
        params = cnn.init_pipeline_params(
            jax.random.PRNGKey(seed), policy.num_stages * virtual_stages,
            width=width)
        bstates = _pipeline_bstates(policy, (data.image, data.image, width),
                                    batch=batch,
                                    microbatches=pipeline_microbatches,
                                    num_samples=data.num_train,
                                    virtual_stages=virtual_stages)
    else:
        params = warmup_params or cnn.init_params(
            jax.random.PRNGKey(seed), width=width)
        if warmup_params is not None:
            params = jax.tree.map(jnp.asarray, warmup_params)
        bstates = _cnn_bstates(policy, data, batch, width)
    opt_state = init_opt_state(opt, params)
    step = make_cnn_train_step(policy, opt, transport=transport, mesh=mesh,
                               stage_axis=stage_axis,
                               pipeline_microbatches=pipeline_microbatches,
                               schedule=schedule,
                               virtual_stages=virtual_stages)

    t0 = time.time()
    curve = []
    for ep in range(epochs):
        accs = []
        for x, y, ids in data.epoch(batch, ep):
            with trace.span("train.step", cat="train", epoch=ep) as sa:
                params, opt_state, bstates, m = step(
                    params, opt_state, bstates, jnp.asarray(x),
                    jnp.asarray(y), jnp.asarray(ids))
                acc = float(m["acc"])            # sync inside the span
                sa["acc"] = round(acc, 6)
            accs.append(acc)
        curve.append(float(np.mean(accs)))
    res = ExperimentResult(name=name or policy.boundary.name,
                           train_curve=curve, seconds=time.time() - t0)
    res.acc_off, res.loss_off = _cnn_eval(params, data, policy, False, batch,
                                          transport)
    res.acc_on, res.loss_on = _cnn_eval(params, data, policy, True, batch,
                                        transport)
    res.params = params
    return res


def _pipeline_bstates(policy: CompressionPolicy, feat_shape, *, batch: int,
                      microbatches=None, num_samples: int = 0,
                      dtype=jnp.float32, virtual_stages: int = 1,
                      dp: int = 1):
    """Feedback state for the real pipeline transport: the stage-stacked
    ``init_feedback_state`` pytree, or ``[]`` for feedback-free policies
    (pass-through, PR-1 behaviour)."""
    from repro.core.policy import BoundaryPolicy
    bp = policy.at(0) if policy.num_boundaries else BoundaryPolicy()
    if not (bp.needs_fw_buffer or bp.needs_bw_buffer):
        return []
    from repro.transport.pipeline import init_feedback_state
    return init_feedback_state(bp, feat_shape, num_stages=policy.num_stages,
                               batch=batch, microbatches=microbatches,
                               num_samples=num_samples, dtype=dtype,
                               virtual_stages=virtual_stages, dp=dp)


def init_lm_dp_state(cfg, params, policy: CompressionPolicy, dp: int,
                     dp_feedback: str = "none", *,
                     transport: str = "simulated", virtual_stages: int = 1,
                     tp: int = 1):
    """DP-reduce state for an LM train step: the residual/aggregate trees
    mirror what actually crosses the data axis — the FULL param tree on
    the simulated transport (vmap lanes differentiate everything per
    replica), the pipelined layer stack on the pipeline transport, and the
    raw layer stack on the simulated DP x TP mesh (embed/head gradients
    stay exact and replicated in both sharded regimes)."""
    from repro.models import transformer
    from repro.transport.collectives import init_dp_state
    if transport == "pipeline":
        like = jax.eval_shape(lambda p: transformer.stack_layer_stages(
            p, policy.num_stages * virtual_stages), params)
    elif tp > 1:
        like = jax.eval_shape(lambda p: p["layers"], params)
    else:
        like = jax.eval_shape(lambda p: p, params)
    return init_dp_state(like, dp, dp_feedback)


def _cnn_bstates(policy: CompressionPolicy, data: ImageClassData,
                 batch: int, width: int):
    shapes = cnn.boundary_shapes(width, data.image)
    states = []
    for i in range(policy.num_boundaries):
        bp = policy.at(i)
        from repro.core.boundary import init_boundary_state
        states.append(init_boundary_state(
            bp, shapes[i], batch=batch, num_samples=data.num_train))
    return states


# ---------------------------------------------------------------------------
# LM fine-tuning (paper Sec. 3.2)
# ---------------------------------------------------------------------------

def _lm_eval(params, cfg, data, policy, compress, batch=16) -> float:
    step = make_lm_eval_step(cfg, policy, compress)
    losses = []
    for toks, _ in data.test_batches(batch):
        losses.append(float(step(params, {"tokens": jnp.asarray(toks)})))
    return float(np.mean(losses))


def run_lm_experiment(cfg: ModelConfig, policy: CompressionPolicy, *,
                      pretrained_params=None, epochs: int = 2,
                      batch: int = 16, data: Optional[LMData] = None,
                      name: str = "",
                      opt: Optional[OptimizerConfig] = None,
                      seed: int = 0, transport: str = "simulated",
                      mesh=None, stage_axis: str = "stage",
                      pipeline_microbatches: Optional[int] = None,
                      schedule: str = "gpipe", virtual_stages: int = 1,
                      dp=_UNSET, dp_codec=_UNSET,
                      dp_feedback=_UNSET, dp_k_frac=_UNSET,
                      parallel: Optional[ParallelSpec] = None,
                      bandwidth_probe=None
                      ) -> ExperimentResult:
    """Fine-tune a (pre-trained) tiny LM with boundary compression.

    ``transport="pipeline"`` runs the layer stack as a real compressed
    ``ppermute`` pipeline (same params/policy as simulated — the
    transformer's layer groups are homogeneous, so the pre-trained weights
    carry over unchanged) under ``schedule`` (gpipe | 1f1b | interleaved).

    ``parallel=`` (a :class:`~repro.core.parallel.ParallelSpec`) sizes and
    wires all three mesh axes in one place: ``data`` (compressed gradient
    all-reduce), ``stage`` (``stages > 1`` implies the pipeline transport)
    and ``tensor`` (compressed TP collectives; the step threads a
    ``tp_state`` buffer for ef/ef21 tensor wires).  Axis codecs may be
    rule specs (``"size>=1e6:q8@0.1; default:none"``) — they resolve
    against this run's wire sizes and the bandwidth probe exactly like
    :class:`PolicyRules`, re-resolving before every epoch.  The
    ``dp``/``dp_codec``/``dp_feedback``/``dp_k_frac`` kwargs are a
    DEPRECATED alias family for the data axis (warns
    ``ParallelDeprecationWarning``; passing both families is an error) —
    they need ``dp`` (simulated) or ``dp * num_stages`` (pipeline)
    devices.

    ``bandwidth_probe``: a zero-arg callable returning a link-bandwidth
    measurement (``obs.probes.probe_mesh`` dict, a ``LinkMeasurement``, a
    plain bytes/s float, or None) — the telemetry loop closing into the
    policy engine.  When ``policy`` is a :class:`PolicyRules` (or the
    spec has rule-coded axes), the probe runs before EVERY epoch and the
    rules re-resolve against the fresh measurement; an unchanged resolved
    policy keeps the step function (and its jit cache), a changed one
    rebuilds the step — a static re-trace, exactly the PR-7 rule-engine
    contract.  Without a probe, rules with ``bandwidth>=X`` terms never
    fire (``matches`` gets bandwidth=None) and the run is bit-identical
    to the static resolution.
    """
    data = data or LMData()
    rules = policy if isinstance(policy, PolicyRules) else None
    bsize = data.seq_len * cfg.d_model
    legacy = {"dp": dp, "dp_codec": dp_codec, "dp_feedback": dp_feedback,
              "dp_k_frac": dp_k_frac}
    explicit = tuple(sorted(k for k, v in legacy.items() if v is not _UNSET))
    spec0 = parallel
    spec_has_rules = (spec0 is not None
                      and any(spec0.axis(n).is_rules
                              for n in ("data", "stage", "tensor")))
    probe_bw = (bandwidth_probe is not None
                and (rules is not None or spec_has_rules))
    bw = boundary_bandwidth(bandwidth_probe()) if probe_bw else None
    if rules is not None:
        policy = resolve_policy(rules, bsize, bandwidth=bw)
    if spec0 is None:
        # fold the deprecated dp_* family into the equivalent spec HERE so
        # the warning names this call site and the step builder (which
        # receives parallel=) never re-warns
        if explicit:
            warn_legacy("run_lm_experiment", explicit)
        vals = {k: (legacy[k] if legacy[k] is not _UNSET else d)
                for k, d in _LEGACY_DEFAULTS.items()}
        spec0 = from_legacy(
            num_stages=(policy.num_stages if transport == "pipeline" else 1),
            **vals)
    elif explicit:
        raise ValueError(
            f"run_lm_experiment: both parallel= and the legacy kwarg(s) "
            f"{list(explicit)} were passed — drop the legacy kwargs")
    opt = opt or OptimizerConfig(kind="adamw", lr=3e-4, weight_decay=0.01,
                                 schedule="constant", grad_clip=1.0)
    params = pretrained_params or transformer.init_params(
        jax.random.PRNGKey(seed), cfg)
    params = jax.tree.map(jnp.asarray, params)
    opt_state = init_opt_state(opt, params)
    feat = (data.seq_len, cfg.d_model)

    # per-axis wire sizes for rule-spec resolution: the data wire carries
    # the gradient tree, stage/tensor wires carry per-example activations
    # (the tensor payload is the 1/tp sequence shard)
    n_grad = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        jax.eval_shape(lambda p: p, params)))
    wire_sizes = {"data": n_grad, "stage": bsize,
                  "tensor": bsize // max(spec0.tp, 1)}

    def resolve_spec(bw):
        return spec0.resolved(wire_sizes, bandwidth=bw)

    spec = resolve_spec(bw) if spec_has_rules else spec0
    # effective (spec, policy, transport) triple — the same pure folding
    # make_lm_train_step applies — for sizing feedback/DP/TP state here
    spec_eff, policy_eff, transport_eff = _resolve_parallel(
        "run_lm_experiment", spec, policy, transport, {})
    dp_n, tp_n = spec_eff.dp, spec_eff.tp

    def build_bstates(policy_eff):
        if transport_eff == "simulated":
            from repro.core.boundary import init_boundary_state
            return [init_boundary_state(
                policy_eff.at(i), feat, batch=batch,
                num_samples=data.num_train, dtype=jnp.bfloat16)
                for i in range(policy_eff.num_boundaries)]
        elif transport_eff == "pipeline":
            return _pipeline_bstates(policy_eff, feat, batch=batch,
                                     microbatches=pipeline_microbatches,
                                     num_samples=data.num_train,
                                     dtype=jnp.bfloat16,
                                     virtual_stages=virtual_stages, dp=dp_n)
        return []

    def build_step(policy, spec):
        return make_lm_train_step(
            cfg, policy, opt, remat=False, donate=False,
            transport=transport, mesh=mesh, stage_axis=stage_axis,
            pipeline_microbatches=pipeline_microbatches,
            schedule=schedule, virtual_stages=virtual_stages,
            parallel=spec)

    bstates = build_bstates(policy_eff)
    step = build_step(policy, spec)
    dp_state = (init_lm_dp_state(cfg, params, policy_eff, dp_n,
                                 spec_eff.data.feedback,
                                 transport=transport_eff,
                                 virtual_stages=virtual_stages, tp=tp_n)
                if dp_n > 1 else None)
    tp_state = None
    if tp_n > 1 and transport_eff == "simulated":
        from repro.transport.tp_collectives import init_tp_state
        tp_state = init_tp_state((batch, data.seq_len, cfg.d_model),
                                 transformer.tp_sites(cfg),
                                 spec_eff.tensor.feedback)

    t0 = time.time()
    curve = []
    policy_curve = []
    for ep in range(epochs):
        if probe_bw and ep > 0:
            # telemetry -> policy: re-resolve the rules against the fresh
            # measurement; rebuild the step ONLY on an actual flip (rule
            # policies and rule axis codecs are shape-stable, so
            # dp/tp/boundary state survives; an unchanged policy keeps
            # every jit cache entry)
            bw = boundary_bandwidth(bandwidth_probe())
            flipped = False
            if rules is not None:
                new_policy = resolve_policy(rules, bsize, bandwidth=bw)
                if new_policy.name != policy.name:
                    trace.instant("policy.flip", cat="policy", epoch=ep,
                                  bandwidth=bw, old=policy.name,
                                  new=new_policy.name)
                    policy = new_policy
                    flipped = True
            if spec_has_rules:
                new_spec = resolve_spec(bw)
                if new_spec.name != spec.name:
                    trace.instant("policy.flip", cat="policy", epoch=ep,
                                  bandwidth=bw, old=spec.name,
                                  new=new_spec.name)
                    spec = new_spec
                    flipped = True
            if flipped:
                spec_eff, policy_eff, transport_eff = _resolve_parallel(
                    "run_lm_experiment", spec, policy, transport, {})
                bstates = build_bstates(policy_eff)
                step = build_step(policy, spec)
        policy_curve.append(policy_eff.name if tp_n == 1
                            else f"{policy_eff.name}/{spec_eff.name}")
        for toks, ids in data.epoch(batch, ep):
            with trace.span("train.step", cat="train", epoch=ep) as sa:
                batch_in = {"tokens": jnp.asarray(toks)}
                args = [params, opt_state, bstates, batch_in,
                        jnp.asarray(ids)]
                if dp_state is not None:
                    args.append(dp_state)
                if tp_state is not None:
                    args.append(tp_state)
                out = step(*args)
                params, opt_state, bstates = out[0], out[1], out[2]
                rest = list(out[3:-1])
                if dp_state is not None:
                    dp_state = rest.pop(0)
                if tp_state is not None:
                    tp_state = rest.pop(0)
                m = out[-1]
                loss = float(m["loss"])          # sync inside the span
                sa["loss"] = round(loss, 6)
            curve.append(loss)
    res = ExperimentResult(name=name or policy_eff.boundary.name,
                           train_curve=curve, seconds=time.time() - t0,
                           policy_curve=policy_curve)
    res.loss_on = _lm_eval(params, cfg, data, policy_eff, True, batch)
    res.loss_off = _lm_eval(params, cfg, data, policy_eff, False, batch)
    res.params = params
    return res


def pretrain_lm(cfg: ModelConfig, *, steps: int = 300, batch: int = 16,
                data: Optional[LMData] = None, seed: int = 0):
    """Uncompressed pre-training for the fine-tuning experiments."""
    data = data or LMData()
    opt = OptimizerConfig(kind="adamw", lr=1e-3, weight_decay=0.01,
                          schedule="constant", grad_clip=1.0)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = init_opt_state(opt, params)
    step = make_lm_train_step(cfg, NO_POLICY, opt, remat=False, donate=False)
    n = 0
    ep = 0
    while n < steps:
        for toks, ids in data.epoch(batch, ep):
            params, opt_state, _, m = step(
                params, opt_state, [], {"tokens": jnp.asarray(toks)},
                jnp.asarray(ids))
            n += 1
            if n >= steps:
                break
        ep += 1
    return params, float(m["loss"])
